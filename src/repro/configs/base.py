"""Configuration schema for the Nightjar reproduction framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig`; mesh / sharding policy lives in
:class:`ParallelConfig`.  Configs are frozen dataclasses so they can be used as
static arguments to ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description covering all assigned families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention details -------------------------------------------------
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"    # rope | learned | none
    logit_softcap: float = 0.0
    max_position_embeddings: int = 1_048_576

    # --- mlp / norms --------------------------------------------------------
    mlp_type: str = "swiglu"       # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    rmsnorm_offset: bool = False   # gemma's (1 + scale) convention
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True

    # --- mixture of experts --------------------------------------------------
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_groups: int = 1   # hierarchical dispatch groups (== batch shards)

    # --- state space (mamba2 / SSD) ------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- hybrid (zamba2) ------------------------------------------------------
    hybrid_attn_every: int = 0     # apply shared attention block every N layers

    # --- encoder-decoder (whisper) ---------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attention: bool = False
    enc_context: int = 1500        # whisper 30s window (decoder cross-KV length)

    # --- vlm (paligemma) ---------------------------------------------------------
    num_image_tokens: int = 0      # bidirectional prefix length

    # --- numerics / compilation ---------------------------------------------------
    dtype: str = "bfloat16"
    scan_layers: bool = True       # stack layer params & lax.scan over layers
    unroll_scans: bool = False     # unroll inner scans (exact HLO flop counts)
    remat: str = "none"            # none | full | dots
    attn_chunk: int = 1024         # kv-chunk for blockwise prefill attention
    xent_chunk: int = 512          # sequence chunk for streamed cross entropy

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid families)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def replace(self, **kw) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ParallelConfig:
    """Mesh & sharding policy."""

    multi_pod: bool = False
    # logical axis assignment
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str = "pod"
    # weight sharding: "tp"   -> weights replicated over data, sharded over model
    #                  "fsdp" -> weights 2D-sharded over (data, model)
    weight_mode: str = "fsdp"
    # KV-cache sequence dim sharded over the model axis (context parallelism)
    context_parallel: bool = True
    # explicit shard_map decode attention instead of XLA auto-SPMD
    explicit_decode_collectives: bool = False

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class DraftConfig:
    """Draft model pairing for speculative decoding (same family/vocab)."""

    target: str
    model: "ModelConfig"
    gamma_max: int = 5


# The four assigned shapes ----------------------------------------------------
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Assigned shapes applicable to an architecture (see DESIGN.md §6)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.is_subquadratic:
        out.append(LONG_500K)
    return tuple(out)
