"""The paper's own experimental pair (DeepSeek-R1-Distill-Qwen-7B target +
DeepSeek-R1-DRAFT-Qwen2.5-0.5B draft) — qwen2-7b architecture.

Used by the faithful-reproduction benchmarks (Tables 3/5/6, Figures 2/9-15).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paper-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=10_000.0,
    tie_embeddings=False,
)

DRAFT = ModelConfig(
    name="paper-7b-draft",      # qwen2.5-0.5B layout
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=152064,
    qkv_bias=True,
    tie_embeddings=True,
)
