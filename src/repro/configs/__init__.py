"""Config registry: assigned architectures, shapes, draft pairings,
ShapeDtypeStruct input specs, and reduced configs for CPU smoke tests."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    shapes_for,
)
from . import (
    deepseek_7b,
    gemma_7b,
    granite_moe_1b,
    grok1_314b,
    mamba2_780m,
    paligemma_3b,
    paper_7b,
    qwen2_72b,
    qwen3_14b,
    whisper_medium,
    zamba2_1p2b,
)

_MODULES = {
    "whisper-medium": whisper_medium,
    "deepseek-7b": deepseek_7b,
    "gemma-7b": gemma_7b,
    "qwen2-72b": qwen2_72b,
    "qwen3-14b": qwen3_14b,
    "grok-1-314b": grok1_314b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "zamba2-1.2b": zamba2_1p2b,
    "paligemma-3b": paligemma_3b,
    "mamba2-780m": mamba2_780m,
    "paper-7b": paper_7b,
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(k for k in _MODULES if k != "paper-7b")


def list_archs():
    return list(_MODULES.keys())


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_draft_config(name: str) -> ModelConfig:
    return _MODULES[name].DRAFT


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# ---------------------------------------------------------------------------
# Reduced configs (smoke tests): same family/features, tiny dimensions
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        vocab_size=256,
        max_position_embeddings=512,
        attn_chunk=64,
        xent_chunk=64,
    )
    if cfg.num_heads:
        kw.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 4) if
                  cfg.num_kv_heads > 1 else 1)
        kw["num_kv_heads"] = 1 if cfg.num_kv_heads == 1 else (
            2 if cfg.num_kv_heads < cfg.num_heads else 4)
        kw["head_dim"] = 32 if cfg.head_dim else 0
    if cfg.d_ff:
        kw["d_ff"] = 256 if not cfg.moe_num_experts else 64
    if cfg.moe_num_experts:
        # cf=8: no capacity drops at smoke scale, so prefill+decode is exactly
        # equivalent to the full forward (dropping is batch-composition
        # dependent by design and tested separately)
        kw.update(moe_num_experts=min(cfg.moe_num_experts, 4),
                  moe_top_k=min(cfg.moe_top_k, 2),
                  moe_capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=32, ssm_chunk=32)
    if cfg.family == "encdec":
        kw.update(enc_layers=2, dec_layers=2, enc_context=32)
    if cfg.family == "hybrid":
        kw.update(num_layers=4, hybrid_attn_every=2)
    if cfg.num_image_tokens:
        kw["num_image_tokens"] = 8
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, batch_override=None) -> Dict:
    """Returns the kwargs pytree for the step function of this (arch, shape).

    train  -> {"batch": {tokens, labels[, enc_emb | image_emb]}}
    prefill-> {"batch": {tokens[, enc_emb | image_emb]}}
    decode -> {"cache": <cache specs>, "tokens": (B, 1)}
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16

    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.family == "encdec":
            dec_len = S if shape.kind == "train" else max(S // 4, 1)
            batch["enc_emb"] = _sds((B, S, cfg.d_model), bf16)
            batch["tokens"] = _sds((B, dec_len), i32)
            if shape.kind == "train":
                batch["labels"] = _sds((B, dec_len), i32)
        elif cfg.family == "vlm":
            n_img = cfg.num_image_tokens
            batch["image_emb"] = _sds((B, n_img, cfg.d_model), bf16)
            batch["tokens"] = _sds((B, S - n_img), i32)
            if shape.kind == "train":
                batch["labels"] = _sds((B, S - n_img), i32)
        else:
            batch["tokens"] = _sds((B, S), i32)
            if shape.kind == "train":
                batch["labels"] = _sds((B, S), i32)
        return {"batch": batch}

    # decode: cache filled to S, one new token
    from ..models import registry as _registry  # local import to avoid cycle

    api = _registry.get_model(cfg)
    if cfg.family == "encdec":
        cache = jax.eval_shape(lambda: api.init_cache(B, S, enc_len=cfg.enc_context))
    else:
        cache = jax.eval_shape(lambda: api.init_cache(B, S))
    return {"cache": cache, "tokens": _sds((B, 1), i32)}
