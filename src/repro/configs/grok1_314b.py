"""grok-1-314b [moe]: 8 experts top-2. 64L d=6144 48H (kv=8) ff=32768
vocab=131072.  [hf:xai-org/grok-1]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    mlp_type="swiglu",
    moe_num_experts=8,
    moe_top_k=2,
    logit_softcap=30.0,
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="grok-1-314b-draft",
    family="dense",
    num_layers=6,
    d_model=1536,
    num_heads=12,
    num_kv_heads=4,
    head_dim=128,
    d_ff=4096,
    vocab_size=131072,
    tie_embeddings=True,
)
