"""qwen2-72b [dense]: GQA kv=8, QKV bias. 80L d=8192 64H ff=29568 vocab=152064.
[arXiv:2407.10671]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

DRAFT = ModelConfig(
    name="qwen2-72b-draft",
    family="dense",
    num_layers=6,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=2816,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
