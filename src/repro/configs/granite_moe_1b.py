"""granite-moe-1b-a400m [moe]: 32 experts top-8. 24L d=1024 16H (kv=8)
ff=512/expert vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mlp_type="swiglu",
    moe_num_experts=32,
    moe_top_k=8,
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="granite-moe-1b-a400m-draft",
    family="dense",
    num_layers=2,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    d_ff=1024,
    vocab_size=49155,
    tie_embeddings=True,
)
