"""qwen3-14b [dense]: qk_norm, GQA kv=8. 40L d=5120 40H ff=17408 vocab=151936.
[hf:Qwen/Qwen3-8B family]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

DRAFT = ModelConfig(
    name="qwen3-14b-draft",
    family="dense",
    num_layers=4,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
