"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.

48L d=1536 ssm_state=128 vocab=50280.  [arXiv:2405.21060]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,            # d_inner=3072, 48 ssm heads
    ssm_chunk=256,
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="mamba2-780m-draft",
    family="ssm",
    num_layers=6,
    d_model=512,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=32,
    ssm_headdim=32,
    ssm_expand=2,
    tie_embeddings=True,
)
