"""paligemma-3b [vlm]: SigLIP (stubbed) + gemma backbone.

18L d=2048 8H (kv=1 MQA) ff=16384 vocab=257216; 256 bidirectional image
prefix tokens supplied as precomputed patch embeddings.  [arXiv:2407.07726]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_type="geglu",
    rmsnorm_offset=True,
    embed_scale=True,
    num_image_tokens=256,
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="paligemma-3b-draft",
    family="dense",
    num_layers=4,
    d_model=768,
    num_heads=4,
    num_kv_heads=1,
    head_dim=128,
    d_ff=2048,
    vocab_size=257216,
    mlp_type="geglu",
    rmsnorm_offset=True,
    embed_scale=True,
    tie_embeddings=True,
)
