"""deepseek-7b [dense]: llama-arch. 30L d=4096 32H (kv=32) ff=11008 vocab=102400.
[arXiv:2401.02954]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102400,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=False,
)

DRAFT = ModelConfig(
    name="deepseek-7b-draft",
    family="dense",
    num_layers=4,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=2048,
    vocab_size=102400,
    tie_embeddings=True,
)
