"""whisper-medium [audio]: enc-dec, conv frontend stubbed.

24L (24 enc + 24 dec), d_model=1024, 16H MHA, d_ff=4096, vocab=51865.
[arXiv:2212.04356]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=48,            # informational: 24 enc + 24 dec below
    enc_layers=24,
    dec_layers=24,
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    pos_embedding="learned",
    max_position_embeddings=65536,
    enc_context=1500,         # 30s audio window for decode-shape cross-KV
    tie_embeddings=True,
)

# shallow decoder-only draft over the same vocabulary (text-side speculation)
DRAFT = ModelConfig(
    name="whisper-medium-draft",
    family="dense",
    num_layers=4,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm_type="rmsnorm",
    tie_embeddings=True,
)
