"""Serving entrypoint.

Real execution tier (reduced configs, actual JAX compute over the paged-KV
runtime; --chunk-tokens also works here — chunked prefill runs on real
execution through RealBackend.hybrid_step):
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --policy nightjar

Analytical paper-scale tier (TPU v5e cost model):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --rate 20 --requests 300 --policy nightjar

Multi-replica cluster on the simulated tier (per-replica planners behind a
router; --rate is the TOTAL arrival rate across the fleet):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --replicas 4 --router jsq --rate 80 --requests 800

Chunked-prefill hybrid batching with a 0.5s TTFT SLO (tail-latency regime):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --rate 30 --requests 600 --chunk-tokens 256 --slo 0.5

Prefix-sharing copy-on-write KV caching on the templated workload (shared
system prompt; cached prefixes cost no prefill compute and no new blocks):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --dataset templated --rate 60 --chunk-tokens 384 --prefix-caching on

Cluster control plane: sticky prefix-affinity routing over a multi-template
workload (each replica's cache specialises on its own templates):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --replicas 2 --router affinity --dataset templated --num-templates 8 \
      --chunk-tokens 384 --prefix-caching on --rate 60

Elastic autoscaling + admission control on a bursty trace (the fleet starts
at 1 replica, grows to --replicas under the spike, sheds hopeless arrivals
at the door, drains back down after):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --replicas 2 --router slo --autoscale --shed-factor 1.5 \
      --dataset alpaca --bursty --requests 400

Host-memory KV offload tier on the multi-turn session workload (evicted
prefix blocks spill to a host-side store and restore on the next turn's
prefix hit instead of re-running prefill):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --dataset sessions --requests 48 --rate 0.5 --chunk-tokens 384 \
      --prefix-caching on --kv-offload

Disaggregated prefill/decode fleet on the mixed long-prompt/long-decode
workload (arrivals prefill on a chunked-prefill pool, then the priced KV
handoff migrates each finished prompt's blocks to a decode replica — or
keeps it local when the transfer would lose):
  PYTHONPATH=src python -m repro.launch.serve --arch paper-7b --tier sim \
      --hw rtx-4090 --dataset mixed --rate 28 --requests 500 \
      --chunk-tokens 128 --max-batch 48 --disaggregate 2:2
(the 24GB 4090 profile: the mixed workload's long prompts need KV headroom
beside the 7B weights, which the 16GB v5e profile does not have)
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-7b")
    ap.add_argument("--policy", default="nightjar")
    ap.add_argument("--tier", choices=["real", "sim"], default="sim")
    ap.add_argument("--rate", type=float, default=10.0)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--dataset", default="sharegpt")
    ap.add_argument("--qa-frac", type=float, default=None,
                    help="document-QA fraction of the mixed long-prompt/"
                         "long-decode dataset (default: the dataset's "
                         "tuned 0.25; only valid with --dataset mixed)")
    ap.add_argument("--gamma-max", type=int, default=5)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--chunk-tokens", default="0",
                    help="per-step prefill token budget for chunked-prefill "
                         "hybrid batching (0 = monolithic; 'auto' derives "
                         "the budget from the roofline compute-bound knee, "
                         "falling back to 256 without a cost model)")
    ap.add_argument("--slo", type=float, default=None,
                    help="TTFT deadline in seconds for SLO-attainment/"
                         "goodput (default: per-dataset; <=0 disables)")
    ap.add_argument("--prefix-caching", choices=["on", "off"], default="off",
                    help="vLLM-style copy-on-write prefix sharing: cached "
                         "prompt prefixes are admitted at refcount+1 and "
                         "skip prefill compute (chunked scheduler path)")
    ap.add_argument("--prefill-order", choices=["fifo", "slo"],
                    default="fifo",
                    help="waiting-queue admission order for chunked "
                         "prefill: FIFO or earliest-TTFT-deadline first")
    ap.add_argument("--kv-offload", action="store_true",
                    help="host-memory KV spill tier: evicted cached-prefix "
                         "blocks move to a host store and restore into free "
                         "device blocks on a later prefix hit (requires "
                         "--prefix-caching on)")
    ap.add_argument("--no-offload", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="sim tier only: number of engine replicas (with "
                         "--autoscale this is the fleet's max size)")
    ap.add_argument("--router", default="jsq",
                    choices=["rr", "jsq", "kv", "slo", "affinity"],
                    help="dispatch policy for --replicas > 1: rr/jsq/kv "
                         "(load / headroom), slo (predicted-TTFT deadline "
                         "headroom), affinity (sticky template-hash routing "
                         "with load-aware spillover)")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic fleet: start at 1 replica, scale up to "
                         "--replicas on a windowed SLO-attainment signal, "
                         "drain back down when load clears")
    ap.add_argument("--shed-factor", type=float, default=0.0,
                    help="admission control: shed an arrival when every "
                         "replica's predicted TTFT exceeds slo * factor "
                         "(0 disables)")
    ap.add_argument("--num-templates", type=int, default=1,
                    help="templated dataset: number of distinct system-"
                         "prompt templates (affinity-routing workload)")
    ap.add_argument("--bursty", action="store_true",
                    help="sim tier: draw arrivals from the bursty "
                         "baseline->spike->drain trace instead of a "
                         "constant-rate Poisson process")
    ap.add_argument("--hw", default="tpu-v5e",
                    choices=["tpu-v5e", "rtx-4090", "a100-40g"],
                    help="sim tier: roofline hardware profile")
    ap.add_argument("--disaggregate", default=None, metavar="P:D",
                    help="sim tier: split the fleet into P prefill + D "
                         "decode replicas with priced KV handoff (requires "
                         "--chunk-tokens > 0; overrides --replicas)")
    ap.add_argument("--handoff-margin", type=float, default=0.0,
                    help="pricer hysteresis in seconds: a handoff must beat "
                         "staying put by at least this much")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="sim tier: deterministic fault schedule, e.g. "
                         "'crash:1@2.0;straggle:0@1.0..3.0x4;"
                         "handoff:fail@0..5#2;corrupt:0@4.0#1' — seeded by "
                         "--seed, so the same spec + seed replays the exact "
                         "same faults (forces the cluster path)")
    ap.add_argument("--brownout", action="store_true",
                    help="sim tier: arm the fleet brownout ladder "
                         "(speculation off -> draft offload -> best_effort "
                         "output cap -> class-ordered shedding, with "
                         "hysteresis and cooldowns; forces the cluster "
                         "path; pairs naturally with --shed-factor)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="flight recorder: write a structured trace of the "
                         "run (request lifecycle spans, engine steps, fleet "
                         "events) to PATH — deterministic, so same seed => "
                         "byte-identical file (analyse with "
                         "benchmarks/trace_report.py)")
    ap.add_argument("--trace-format", choices=["jsonl", "chrome"],
                    default="jsonl",
                    help="trace file format: jsonl (trace_report.py input) "
                         "or chrome (Perfetto / chrome://tracing viewable)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry in Prometheus text "
                         "exposition format to PATH at end of run")
    args = ap.parse_args()

    if args.kv_offload and args.prefix_caching != "on":
        ap.error("--kv-offload requires --prefix-caching on (the host tier "
                 "is keyed by prefix chain hashes)")
    if args.fault_plan is not None and args.tier != "sim":
        ap.error("--fault-plan runs on the simulated tier only (the "
                 "injector is driven by the shared virtual clock)")
    if args.brownout and args.tier != "sim":
        ap.error("--brownout runs on the simulated tier only (the ladder "
                 "is driven by the shared virtual clock)")

    from .. import configs

    trace_rec = None
    if args.trace or args.metrics_out:
        from ..serving.observability import TraceRecorder
        trace_rec = TraceRecorder()

    if args.tier == "sim":
        from ..serving.costmodel import (A100_40G, RTX_4090,
                                         RooflineCostModel, TPU_V5E)
        from ..serving.simulator import (SimConfig, build_sim_cluster,
                                         build_sim_engine)
        from ..serving.workload import (bursty_trace, mixed_requests,
                                        poisson_requests, session_requests,
                                        templated_requests)

        hw = {"tpu-v5e": TPU_V5E, "rtx-4090": RTX_4090,
              "a100-40g": A100_40G}[args.hw]
        target = configs.get_config(args.arch)
        chunk = RooflineCostModel(hw).resolve_chunk_tokens(
            args.chunk_tokens, target)
        cfg = SimConfig(
            target=target,
            draft=configs.get_draft_config(args.arch),
            hw=hw, gamma_max=args.gamma_max, max_batch=args.max_batch,
            chunk_tokens=chunk,
            prefix_caching=args.prefix_caching == "on",
            prefill_order=args.prefill_order,
            enable_offload=not args.no_offload,
            kv_offload=args.kv_offload, seed=args.seed)
        if args.dataset in ("templated", "sessions") and args.bursty:
            ap.error(f"--bursty is not supported with --dataset "
                     f"{args.dataset} (that workload generates its own "
                     f"arrival process); pick one")
        if args.dataset == "sessions":
            # --requests is the TOTAL request budget; each session
            # contributes `turns` requests (one per conversation turn)
            from ..serving.workload import DATASETS
            turns = DATASETS["sessions"]["turns"]
            reqs = session_requests(max(args.requests // turns, 1),
                                    rate_qps=args.rate, seed=args.seed + 1,
                                    slo=args.slo)
        elif args.dataset == "templated":
            # prompts carry real token ids (shared template + suffix) so
            # the prefix cache has content to hash and the affinity router
            # has an identity to be sticky about
            reqs = templated_requests(args.rate, args.requests,
                                      num_templates=args.num_templates,
                                      seed=args.seed + 1, slo=args.slo)
        elif args.dataset == "mixed":
            if args.bursty:
                ap.error("--bursty is not supported with --dataset mixed")
            reqs = mixed_requests(args.rate, args.requests,
                                  qa_frac=args.qa_frac,
                                  seed=args.seed + 1, slo=args.slo)
        elif args.bursty:
            trace = bursty_trace(seed=args.seed)
            reqs = trace.sample_requests(args.requests, dataset=args.dataset,
                                         seed=args.seed + 1, slo=args.slo)
        else:
            reqs = poisson_requests(args.rate, args.requests,
                                    dataset=args.dataset, seed=args.seed + 1,
                                    slo=args.slo)
        if args.qa_frac is not None and args.dataset != "mixed":
            ap.error("--qa-frac only applies to --dataset mixed")
        disaggregate = None
        if args.disaggregate is not None:
            try:
                p, d = (int(x) for x in args.disaggregate.split(":"))
            except ValueError:
                ap.error("--disaggregate takes P:D (e.g. 2:2)")
            if p < 1 or d < 1:
                ap.error("--disaggregate needs at least one replica per pool")
            if chunk <= 0:
                ap.error("--disaggregate requires --chunk-tokens > 0 "
                         "(the prefill pool runs chunked prefill)")
            disaggregate = dict(prefill=p, decode=d,
                                margin_s=args.handoff_margin)
        fault_plan = None
        if args.fault_plan is not None:
            from ..serving.faults import FaultPlan
            try:
                fault_plan = FaultPlan.parse(args.fault_plan)
            except ValueError as e:
                ap.error(f"--fault-plan: {e}")
        brownout = None
        if args.brownout:
            brownout = dict(slo=args.slo if args.slo else 1.0)
        if (args.replicas > 1 or args.autoscale or args.shed_factor > 0
                or disaggregate is not None or fault_plan is not None
                or brownout is not None):
            autoscale = (dict(min_replicas=1, max_replicas=args.replicas)
                         if args.autoscale else None)
            cluster = build_sim_cluster(
                cfg, args.replicas, args.policy, router=args.router,
                shed_factor=args.shed_factor or None, autoscale=autoscale,
                disaggregate=disaggregate, fault_plan=fault_plan,
                brownout=brownout, trace=trace_rec)
            metrics = cluster.run(reqs)
        else:
            engine = build_sim_engine(cfg, args.policy, trace=trace_rec)
            metrics = engine.run(reqs)
    else:
        from ..core.bandits import make_policy
        from ..models import registry
        from ..serving.costmodel import RooflineCostModel, TPU_V5E
        from ..serving.engine import ServingEngine
        from ..serving.kv_cache import BlockManager
        from ..serving.memory_manager import ElasticMemoryManager
        from ..serving.paged_runtime import num_blocks_for
        from ..serving.real_backend import RealBackend, make_real_backend
        from ..serving.scheduler import ContinuousBatchingScheduler
        from ..serving.workload import tiny_requests

        cfg = configs.reduced(configs.get_config(args.arch))
        dcfg = configs.reduced(configs.get_draft_config(args.arch))
        target, draft = registry.get_model(cfg), registry.get_model(dcfg)
        # the real tier has no wall-clock cost model: 'auto' falls back
        chunk = None
        if args.chunk_tokens != "0":
            chunk = 256 if args.chunk_tokens == "auto" else int(args.chunk_tokens)
        # ONE BlockManager drives both scheduler admission and the physical
        # paged pool, sized from the roofline HBM budget
        cm = RooflineCostModel(TPU_V5E)
        block_size = 8
        nb = num_blocks_for(cm, cfg, dcfg, block_size, max_blocks=1024)
        host_store = None
        if args.kv_offload:
            from ..serving.kv_cache import HostKVStore
            host_store = HostKVStore(4 * nb)
        bm = BlockManager(nb, block_size,
                          prefix_caching=args.prefix_caching == "on",
                          host_store=host_store)
        backend = make_real_backend(target, draft, max_batch=4, max_seq=256,
                                    seed=args.seed, block_manager=bm,
                                    cost_model=cm)
        sched = ContinuousBatchingScheduler(bm, max_batch=4,
                                            chunk_tokens=chunk,
                                            prefill_order=args.prefill_order)
        memmgr = None
        if not args.no_offload and isinstance(backend, RealBackend):
            # offload-driven elastic expansion of the PHYSICAL paged pool:
            # the draft's weight bytes converted to KV blocks, clamped so a
            # tiny reduced model still exercises the grow/migrate path
            draft_blocks = max(min(
                -(-cm.weight_bytes(dcfg) // backend.tkv.bytes_per_block),
                bm.total_blocks // 4), 1)
            memmgr = ElasticMemoryManager(
                bm, draft_blocks=int(draft_blocks),
                offload_fn=backend.offload_draft,
                reload_fn=backend.reload_draft,
                migrate_fn=backend.migrate_pools,
                grow_fn=backend.grow_pools,
                shrink_fn=backend.shrink_pools)
        engine = ServingEngine(backend, sched,
                               make_policy(args.policy, 3, seed=args.seed),
                               memmgr, gamma_max=3)
        if trace_rec is not None:
            engine.attach_trace(trace_rec)
        reqs = tiny_requests(min(args.requests, 16), rate_qps=args.rate,
                             prompt_len=16, output_len=16,
                             vocab=cfg.vocab_size, seed=args.seed,
                             template_len=(8 if args.dataset == "templated"
                                           else 0))
        metrics = engine.run(reqs, max_steps=5000)

    if trace_rec is not None:
        if args.trace:
            trace_rec.export(args.trace, fmt=args.trace_format)
        if args.metrics_out:
            with open(args.metrics_out, "w", encoding="utf-8",
                      newline="\n") as f:
                f.write(trace_rec.registry.exposition())

    print(json.dumps(metrics.summary(), indent=1))


if __name__ == "__main__":
    main()
