import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract memory / cost / collective statistics.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape decode_32k --spec

The first two lines of this file pin 512 placeholder CPU devices BEFORE any
jax import so jax.make_mesh can build the (2, 16, 16) production mesh.
"""
import argparse
import json
import re
import sys
from collections import Counter

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# reuse compilations across dry-run invocations (same lowering -> cache hit)
jax.config.update("jax_compilation_cache_dir", "/tmp/jax_cache")
jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1)

from .. import configs
from ..configs.base import ShapeConfig, shapes_for
from ..distributed import sharding as shd
from ..models import registry
from .mesh import make_production_mesh, mesh_context

# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16e9

_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*?\s*([a-z0-9]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum per-device operand bytes of every collective op in (partitioned) HLO."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "f64": 8, "s8": 1, "u8": 1, "pred": 1, "s64": 8, "f8e4m3": 1}
    total = 0
    counts = Counter()
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*\b"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)", line)
        if not m:
            continue
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * dtype_bytes.get(dt, 4)
        counts[kind] += 1
    return total, dict(counts)


def _cpu_upcast_artifact(hlo_text: str) -> int:
    """Bytes of loop-carried f32 copies of bf16 buffers that the CPU backend
    introduces (it has no native bf16 dot, so LICM hoists whole-array f32
    converts out of the layer scan).  These cannot occur on the TPU backend
    (native bf16 MXU) — see EXPERIMENTS §Dry-run.  Detected as >=0.5 GB f32
    dynamic-update-slice buffers (the f32 shadow copies of scanned caches)."""
    total = 0
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*f32\[([0-9,]+)\][^=]*\bdynamic-update-slice", line)
        if not m:
            continue
        n = 4
        for d in m.group(1).split(","):
            n *= int(d)
        if n >= 5e8:  # each op line is one distinct f32 shadow buffer
            total += n
    return total


def _weight_mode(cfg, shape_kind: str, mesh) -> str:
    """Serving prefers TP weights (replicated over data) when they fit;
    decode uses the context-parallel attention sharding (tp_seq)."""
    if shape_kind == "train":
        return "fsdp"
    tp = mesh.shape["model"]
    per_chip = registry.param_bytes(cfg) / tp
    if per_chip >= 0.55 * HBM_BYTES:
        return "fsdp"
    return "tp_seq" if shape_kind == "decode" else "tp"


def build_cell(arch: str, shape: ShapeConfig, mesh, *, spec_decode=False,
               remat: str = "full", cfg_override=None, accum_override=None):
    """Returns (fn, args, in_shardings, out_shardings-ish, meta)."""
    cfg = configs.get_config(arch)
    if shape.kind == "train":
        cfg = cfg.replace(remat=remat)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    if cfg.moe_num_experts:
        # group-local dispatch aligned with the data-parallel shards
        import numpy as _np
        n_batch_shards = int(_np.prod(
            [mesh.shape[a] for a in ("pod", "data") if a in mesh.shape]))
        cfg = cfg.replace(moe_groups=n_batch_shards)
        if shape.kind != "train":
            # inference dispatch: no capacity slack (drops are rare and
            # lossless-irrelevant at prefill; saves 20% dispatch memory)
            cfg = cfg.replace(moe_capacity_factor=1.0)
    api = registry.get_model(cfg)
    mode = _weight_mode(cfg, shape.kind, mesh)

    pspecs = shd.param_specs(cfg, registry.param_specs(cfg), mesh,
                             weight_mode=mode)
    p_sh = shd.to_named(pspecs, mesh)
    param_structs = registry.param_specs(cfg)
    ba = shd.batch_axes(mesh)

    ins = configs.input_specs(cfg, shape)

    if shape.kind == "train":
        from ..training.train_loop import make_train_step
        from ..training.optimizer import adamw_init
        # microbatch accumulation keeps big-model activations inside HBM
        nparams = registry.param_count(cfg)
        accum = 8 if nparams > 60e9 else (4 if nparams > 25e9 else
                                          (2 if nparams > 5e9 else 1))
        if cfg.moe_num_experts:
            accum = max(accum, 2)  # halve the per-micro dispatch buffers
        if accum_override is not None:
            accum = accum_override
        if accum > 1:
            ins["batch"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (accum, s.shape[0] // accum) + s.shape[1:], s.dtype),
                ins["batch"])
        # >100B models: bf16 second moment + bf16 grad accumulation to fit
        # the AdamW state inside 16 GB/chip (DESIGN.md §4)
        huge = nparams > 100e9
        accum_dtype = jnp.bfloat16 if huge else jnp.float32
        mv_dtype = jnp.bfloat16 if huge else jnp.float32
        _, train_step = make_train_step(cfg, accum=accum,
                                        accum_dtype=accum_dtype)
        opt_structs = jax.eval_shape(
            lambda p: adamw_init(p, m_dtype=mv_dtype, v_dtype=mv_dtype),
            param_structs)
        opt_specs = jax.tree.map(
            lambda s: s if isinstance(s, P) else None, {
                "m": pspecs, "v": pspecs})
        o_sh = {"step": NamedSharding(mesh, P()),
                "m": shd.to_named(pspecs, mesh),
                "v": shd.to_named(pspecs, mesh)}
        from ..training.optimizer import AdamWState
        o_sh = AdamWState(step=NamedSharding(mesh, P()),
                          m=shd.to_named(pspecs, mesh),
                          v=shd.to_named(pspecs, mesh))
        if accum > 1:
            def _mb_spec(path, leaf):
                dims = [None] * len(leaf.shape)
                dims[1] = ba
                if shd._leaf_name(path) == "enc_emb" and \
                        leaf.shape[2] % mesh.shape["model"] == 0:
                    dims[2] = "model"
                return P(*dims)
            b_specs = jax.tree_util.tree_map_with_path(_mb_spec, ins["batch"])
        else:
            b_specs = shd.data_specs(cfg, ins["batch"], mesh)
        b_sh = shd.to_named(b_specs, mesh)

        def fn(params, opt, batch):
            with shd.activation_sharding(ba, "model"):
                return train_step(params, opt, batch)

        args = (param_structs, opt_structs, ins["batch"])
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = ({"loss": NamedSharding(mesh, P()),
                   "grad_norm": NamedSharding(mesh, P())}, p_sh, o_sh)
        donate = (0, 1)
    elif shape.kind == "prefill":
        b_specs = shd.data_specs(cfg, ins["batch"], mesh)
        b_sh = shd.to_named(b_specs, mesh)
        max_len = shape.seq_len
        cache_structs = jax.eval_shape(
            lambda: _cache_for(api, cfg, shape))
        c_sh = shd.to_named(shd.cache_specs(cfg, cache_structs, mesh), mesh)

        def fn(params, batch):
            with shd.activation_sharding(ba, "model"):
                logits, cache = api.prefill(params, batch, max_len)
            return logits, cache

        args = (param_structs, ins["batch"])
        in_sh = (p_sh, b_sh)
        out_sh = (NamedSharding(mesh, P()), c_sh)
        donate = ()
    else:  # decode
        cache_structs = ins["cache"]
        c_sh = shd.to_named(shd.cache_specs(cfg, cache_structs, mesh), mesh)
        t_sh = shd.to_named(shd.token_specs(ins["tokens"], mesh), mesh)

        if not spec_decode:
            def fn(params, cache, tokens):
                with shd.activation_sharding(ba, "model"):
                    return api.decode_step(params, cache, tokens)
            args = (param_structs, cache_structs, ins["tokens"])
            in_sh = (p_sh, c_sh, t_sh)
            out_sh = (NamedSharding(mesh, P()), c_sh)
            donate = (1,)
        else:
            from ..core.spec_decode import make_spec_step
            dcfg = configs.get_draft_config(arch)
            dapi = registry.get_model(dcfg)
            d_structs = registry.param_specs(dcfg)
            dp_sh = shd.to_named(
                shd.param_specs(dcfg, d_structs, mesh, weight_mode="tp"), mesh)
            B = shape.global_batch
            dc_structs = jax.eval_shape(lambda: dapi.init_cache(B, shape.seq_len))
            dc_sh = shd.to_named(shd.cache_specs(dcfg, dc_structs, mesh), mesh)
            gamma = 3
            step = make_spec_step(registry.get_model(cfg), dapi)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            last = jax.ShapeDtypeStruct((B,), jnp.int32)

            def fn(k, tp, dp, tc, dc, lt):
                return step(k, tp, dp, tc, dc, lt, gamma=gamma)
            args = (key, param_structs, d_structs, cache_structs,
                    dc_structs, last)
            in_sh = (NamedSharding(mesh, P()), p_sh, dp_sh, c_sh, dc_sh,
                     shd.to_named(shd.token_specs(last, mesh), mesh))
            out_sh = None
            donate = (3, 4)

    meta = {"arch": arch, "shape": shape.name, "kind": shape.kind,
            "weight_mode": mode, "params": registry.param_count(cfg)}
    return fn, args, in_sh, out_sh, donate, meta


def _cache_for(api, cfg, shape):
    B = shape.global_batch
    if cfg.family == "encdec":
        # prefill encodes shape.seq_len frames -> cross-KV length = seq_len;
        # decode shapes use the fixed 30 s window (cfg.enc_context)
        enc_len = shape.seq_len if shape.kind == "prefill" else cfg.enc_context
        # decoder prompt is seq_len // 4 at prefill (DESIGN.md §6)
        dec_len = max(shape.seq_len // 4, 1) if shape.kind == "prefill" \
            else shape.seq_len
        return api.init_cache(B, dec_len, enc_len=enc_len)
    return api.init_cache(B, shape.seq_len)


def run_cell(arch: str, shape: ShapeConfig, mesh, *, spec_decode=False,
             verbose=True, cfg_override=None, accum_override=None):
    fn, args, in_sh, out_sh, donate, meta = build_cell(
        arch, shape, mesh, spec_decode=spec_decode, cfg_override=cfg_override,
        accum_override=accum_override)
    with mesh_context(mesh):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo = compiled.as_text()
    coll_bytes, coll_counts = collective_bytes(hlo)
    upcast_artifact = _cpu_upcast_artifact(hlo)

    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    n_chips = mesh.devices.size

    # memory_analysis is per-device under SPMD (verified empirically)
    peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    peak_tpu = peak - upcast_artifact  # artifact absent on the TPU backend
    arg_bytes = ma.argument_size_in_bytes

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    nparams = (registry.active_param_count(configs.get_config(arch))
               if shape.kind != "train" else meta["params"])
    model_flops = (6 if shape.kind == "train" else 2) * nparams * tokens

    res = {
        **meta,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": n_chips,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll_counts,
        "peak_bytes_per_device": peak,
        "cpu_upcast_artifact_bytes": upcast_artifact,
        "peak_bytes_tpu_adjusted": peak_tpu,
        "argument_bytes_per_device": arg_bytes,
        "temp_bytes_per_device": ma.temp_size_in_bytes,
        "ideal_memory_s": arg_bytes / HBM_BW,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
        "model_flops_global": model_flops,
        "useful_flops_ratio": model_flops / max(flops * n_chips, 1.0),
        "fits_hbm": peak_tpu < HBM_BYTES,
    }
    terms = {"compute": res["compute_s"], "memory": res["memory_s"],
             "collective": res["collective_s"]}
    res["bottleneck"] = max(terms, key=terms.get)
    res["step_time_s"] = max(terms.values())
    res["roofline_fraction"] = (
        model_flops / n_chips / PEAK_FLOPS) / max(res["step_time_s"], 1e-30)
    if verbose:
        print(f"[{meta['arch']} x {shape.name} @ {res['mesh']} "
              f"mode={meta['weight_mode']}]")
        print(f"  memory_analysis: peak/device = {peak/1e9:.2f} GB raw, "
              f"{peak_tpu/1e9:.2f} GB tpu-adjusted "
              f"(fits 16GB: {res['fits_hbm']})")
        print(f"  cost_analysis: flops/dev={flops:.3e} bytes/dev="
              f"{bytes_accessed:.3e}")
        print(f"  collectives: {coll_counts} bytes/dev={coll_bytes:.3e}")
        print(f"  roofline terms (s): compute={res['compute_s']:.4f} "
              f"memory={res['memory_s']:.4f} "
              f"collective={res['collective_s']:.4f} "
              f"-> bottleneck={res['bottleneck']}")
        print(f"  MODEL_FLOPS/HLO_FLOPS={res['useful_flops_ratio']:.3f} "
              f"roofline_fraction={res['roofline_fraction']:.3f}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="lower the speculative (draft+verify) serve step")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = []
    if args.all:
        for arch in configs.ASSIGNED_ARCHS:
            for shape in shapes_for(configs.get_config(arch)):
                cells.append((arch, shape))
    else:
        cfg = configs.get_config(args.arch)
        if args.shape:
            cells = [(args.arch, configs.get_shape(args.shape))]
        else:
            cells = [(args.arch, s) for s in shapes_for(cfg)]

    results = []
    failures = []
    for mesh in meshes:
        for arch, shape in cells:
            try:
                results.append(run_cell(arch, shape, mesh,
                                        spec_decode=args.spec))
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape.name, str(e)[:500]))
                print(f"FAILED {arch} x {shape.name}: {str(e)[:300]}",
                      file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"\n{len(results)} cells compiled, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", f_[0], f_[1])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
