import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Structural roofline extraction — exact per-cell flop/byte/collective terms.

``cost_analysis()`` counts a ``lax.scan`` body ONCE regardless of trip count,
so the whole-step dry-run (which proves compilation + memory fit) undercounts
compute for scanned layers.  This tool recovers exact totals structurally:

  lower the same cell with the layer loop UNROLLED at two small depths
  (L1, L2), take the marginal per-layer cost, and extrapolate:

      total(L) = cost(L1) + (L - L1) * (cost(L2) - cost(L1)) / (L2 - L1)

All inner scans (attention kv-chunks, xent chunks, SSD chunks, grad-accum)
are also unrolled for these lowerings (``unroll_scans=True``, accum=1), so
the marginal captures them exactly.  Memory figures still come from the
full-depth compile (dryrun_*.json) where scan semantics are correct.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --all --json roofline.json
"""
import argparse
import json
import sys

import jax

from .. import configs
from ..configs.base import shapes_for
from ..models import registry
from . import dryrun
from .mesh import make_production_mesh

TERMS = ("flops_per_device", "bytes_per_device",
         "collective_bytes_per_device")


def _depth_override(cfg, L):
    """Config overrides putting the model at depth L, fully unrolled."""
    ov = dict(scan_layers=False, unroll_scans=True)
    if cfg.family == "encdec":
        ov.update(enc_layers=L, dec_layers=L, num_layers=2 * L)
    elif cfg.family == "hybrid":
        ov.update(num_layers=L)  # keep attn_every: shared blocks scale too
    else:
        ov.update(num_layers=L)
    return ov


def _full_depth(cfg):
    return cfg.enc_layers if cfg.family == "encdec" else cfg.num_layers


def structural_cell(arch, shape, mesh, *, verbose=True):
    cfg = configs.get_config(arch)
    if cfg.family == "hybrid":
        L1, L2 = cfg.hybrid_attn_every, 2 * cfg.hybrid_attn_every
    else:
        L1, L2 = 2, 4
    accum = 1 if shape.kind == "train" else None

    costs = {}
    for L in (L1, L2):
        res = dryrun.run_cell(arch, shape, mesh, verbose=False,
                              cfg_override=_depth_override(cfg, L),
                              accum_override=accum)
        costs[L] = res

    Lf = _full_depth(cfg)
    out = dict(costs[L1])  # metadata template
    for term in TERMS:
        marginal = (costs[L2][term] - costs[L1][term]) / (L2 - L1)
        out[term] = costs[L1][term] + marginal * (Lf - L1)
        out[f"{term}_per_layer"] = marginal

    out.update({
        "arch": arch, "shape": shape.name,
        "structural": True, "L1": L1, "L2": L2, "depth": Lf,
        "compute_s": out["flops_per_device"] / dryrun.PEAK_FLOPS,
        "memory_s": out["bytes_per_device"] / dryrun.HBM_BW,
        "collective_s": (out["collective_bytes_per_device"]
                         / dryrun.LINK_BW),
    })
    terms = {"compute": out["compute_s"], "memory": out["memory_s"],
             "collective": out["collective_s"]}
    out["bottleneck"] = max(terms, key=terms.get)
    out["step_time_s"] = max(terms.values())

    n_chips = mesh.devices.size
    cfgf = configs.get_config(arch)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    nparams = (registry.param_count(cfgf) if shape.kind == "train"
               else registry.active_param_count(cfgf))
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * nparams * tokens
    out["model_flops_global"] = model_flops
    out["useful_flops_ratio"] = model_flops / max(
        out["flops_per_device"] * n_chips, 1.0)
    # ideal time: compute bound OR unavoidable HBM reads (params+cache+inputs)
    ideal_compute = model_flops / n_chips / dryrun.PEAK_FLOPS
    ideal_mem = out.get("argument_bytes_per_device", 0) / dryrun.HBM_BW
    out["ideal_s"] = max(ideal_compute, ideal_mem)
    out["roofline_fraction"] = out["ideal_s"] / max(out["step_time_s"], 1e-30)

    if verbose:
        print(f"[structural {arch} x {shape.name} @ "
              f"{'x'.join(str(s) for s in mesh.devices.shape)}]")
        print(f"  terms(s): compute={out['compute_s']:.4f} "
              f"memory={out['memory_s']:.4f} "
              f"collective={out['collective_s']:.4f} "
              f"-> {out['bottleneck']}  "
              f"(useful_flops={out['useful_flops_ratio']:.3f}, "
              f"roofline_frac={out['roofline_fraction']:.3f})")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cells = []
    if args.all:
        for arch in configs.ASSIGNED_ARCHS:
            for shape in shapes_for(configs.get_config(arch)):
                cells.append((arch, shape))
    elif args.shape:
        cells = [(args.arch, configs.get_shape(args.shape))]
    else:
        cells = [(args.arch, s)
                 for s in shapes_for(configs.get_config(args.arch))]

    results, failures = [], []
    for arch, shape in cells:
        try:
            results.append(structural_cell(arch, shape, mesh))
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape.name, str(e)[:300]))
            print(f"FAILED {arch} x {shape.name}: {str(e)[:200]}",
                  file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print(f"{len(results)} structural cells, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
