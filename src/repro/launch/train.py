"""Training entrypoint (single host; the dry-run covers the production mesh).

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
      --steps 50 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from .. import configs
    from ..training.data import make_batch_iter
    from ..training.train_loop import train

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    it = make_batch_iter(cfg.vocab_size, args.batch, args.seq)
    out = train(cfg, steps=args.steps, batch_iter=it,
                checkpoint_dir=args.ckpt_dir)
    for h in out["history"]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f}")
    print(f"final loss {out['final_loss']:.4f} in {out['elapsed_s']:.1f}s")


if __name__ == "__main__":
    main()
