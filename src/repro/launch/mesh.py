"""Production mesh construction.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod: (2, 16, 16) over ("pod", "data", "model") — 512 chips; the `pod`
axis is pure data parallelism (serving replicas / gradient all-reduce), so
elastic scaling adds or removes pods without resharding the model axes.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (8 host devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
