"""Production mesh construction.

Single pod: (16, 16) over ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod: (2, 16, 16) over ("pod", "data", "model") — 512 chips; the `pod`
axis is pure data parallelism (serving replicas / gradient all-reduce), so
elastic scaling adds or removes pods without resharding the model axes.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).

The helpers paper over JAX API drift: ``axis_types``/``AxisType`` only
exist on newer releases, and ``jax.set_mesh`` replaced the plain Mesh
context manager — ``mesh_context`` returns whichever this version has.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    axis_type = getattr(getattr(jax, "sharding"), "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU multi-device tests (8 host devices)."""
    return _make_mesh((data, model), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available, else the Mesh context
    manager — both scope the default mesh for jit'd computations."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
